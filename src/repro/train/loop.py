"""Training loop: drives the decentralized (or baseline) train step, logs the
paper's gradient statistics, and periodically checkpoints.

This is the host-side orchestration layer; the math lives in
``repro.core.decentralized``.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Iterable

import jax
import numpy as np

from repro import compat, telemetry
from repro.core.decentralized import StepMetrics, TrainState, init_state, make_train_step
from repro.core.gossip import GossipSpec
from repro.optim import Optimizer
from repro.train import checkpoint as ckpt_lib

PyTree = Any


@dataclasses.dataclass
class History:
    loss: list[float] = dataclasses.field(default_factory=list)
    grad_energy: list[float] = dataclasses.field(default_factory=list)
    grad_spread: list[float] = dataclasses.field(default_factory=list)
    mean_grad_norm: list[float] = dataclasses.field(default_factory=list)
    param_spread: list[float] = dataclasses.field(default_factory=list)
    step_time: list[float] = dataclasses.field(default_factory=list)

    def append(self, m: StepMetrics, dt: float) -> None:
        self.loss.append(float(m.loss))
        self.grad_energy.append(float(m.grad_energy))
        self.grad_spread.append(float(m.grad_spread))
        self.mean_grad_norm.append(float(m.mean_grad_norm))
        self.param_spread.append(float(m.param_spread))
        self.step_time.append(dt)

    def extend_from_device(self, pending: list[StepMetrics],
                           window_start: float) -> None:
        """Batched host transfer: ONE device_get for a whole log window.

        The per-step ``float()`` calls in :meth:`append` each forced a
        device→host sync, serializing dispatch with the device — five
        blocking transfers *per step*. Here the device arrays accumulate
        asynchronously and land in one ``jax.device_get`` per ``log_every``
        window (EXPERIMENTS.md §Perf, "Batched metric host-sync").

        The window is clocked AFTER the (blocking) transfer: device_get
        waits for every step in the window to finish, so the recorded
        per-step time covers real execution, not just async dispatch.
        """
        if not pending:
            return
        host = jax.device_get(pending)
        dt = (time.perf_counter() - window_start) / len(pending)
        for m in host:
            self.append(m, dt)

    def as_arrays(self) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in dataclasses.asdict(self).items()}


def train(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    params0: PyTree,
    optimizer: Optimizer,
    batches: Iterable[PyTree],
    *,
    steps: int,
    gossip: GossipSpec | None = None,
    mode: str = "gossip",
    mesh=None,
    param_specs: PyTree | None = None,
    log_every: int = 50,
    ckpt_path: str | None = None,
    ckpt_every: int = 0,
    ckpt_sharded: bool = False,
    verbose: bool = True,
) -> tuple[TrainState, History]:
    """Run `steps` iterations; `batches` yields per-step batch pytrees.

    ``mesh`` accepts a raw jax mesh or a :class:`~repro.launch.mesh.WorkerMesh`;
    ``param_specs`` (shardings.param_pspecs output) composes gossip with
    model-sharded replicas — see core/bus.mix_bus.

    Host/device sync discipline: metrics are NOT fetched per step — device
    arrays accumulate and transfer in one batch per ``log_every`` window
    (plus checkpoint/final boundaries), so step dispatch runs ahead of the
    device instead of blocking five times per iteration. Checkpoints follow
    the same discipline: saves go through
    :class:`~repro.train.checkpoint.AsyncCheckpointWriter` — a device-side
    snapshot (safe against the donated state) handed to a background writer
    thread — so the synchronous ``np.savez`` never stalls the loop.
    ``ckpt_sharded=True`` writes per-worker shard files keyed by the
    WorkerMesh coordinates (``checkpoint.save_sharded``) instead of
    device-getting the full stacked tree on one host.
    """
    # Donating the state makes the step in-place on HBM: the params / opt
    # buffers (and the gossip bus pack buffers) reuse the incoming allocation
    # instead of doubling the parameter footprint every iteration. The
    # caller's params0 leaves are copied first — donation would otherwise
    # delete them out from under the caller on backends where it is real.
    from repro.launch.mesh import WorkerMesh

    raw_mesh = WorkerMesh.raw(mesh)
    step_fn = jax.jit(make_train_step(loss_fn, optimizer, gossip=gossip,
                                      mode=mode, mesh=mesh,
                                      param_specs=param_specs),
                      donate_argnums=(0,))
    params0 = jax.tree.map(lambda x: x.copy() if hasattr(x, "copy") else x,
                           params0)
    state = init_state(params0, optimizer)
    hist = History()
    it = iter(batches)
    pending: list[StepMetrics] = []
    t_win = time.perf_counter()
    # Telemetry rides the existing amortized boundaries: one emit batch per
    # log window (inside flush), nothing per step. With the null sink the
    # only cost is this truthiness check — the numerics are untouched either
    # way, so instrumented-but-disabled train() bit-matches plain train().
    tel = telemetry.get()

    def flush() -> None:
        nonlocal t_win
        n = len(pending)
        if tel.active and n:
            with tel.span("train.host_sync", steps=n):
                hist.extend_from_device(pending, t_win)
            dur = time.perf_counter() - t_win
            tel.complete("train.window", tel.now() - dur, dur, steps=n)
            tel.counter("train.steps", n)
            tel.gauge("train.loss", hist.loss[-1])
        else:
            hist.extend_from_device(pending, t_win)
        pending.clear()
        t_win = time.perf_counter()

    writer = ckpt_lib.AsyncCheckpointWriter() if ckpt_path else None
    ckpt_kw = {}
    if ckpt_sharded:
        ckpt_kw = dict(sharded=True,
                       wmesh=mesh if isinstance(mesh, WorkerMesh) else None)
    ctx = compat.set_mesh(raw_mesh) if raw_mesh is not None else _nullcontext()
    try:
        with ctx:
            for k in range(steps):
                batch = next(it)
                state, metrics = step_fn(state, batch)
                pending.append(metrics)
                if k % log_every == 0 or k == steps - 1:
                    flush()
                    if verbose:
                        print(f"step {k:5d}  loss {hist.loss[-1]:.5f}  "
                              f"E {hist.grad_energy[-1]:.3e}  Esp {hist.grad_spread[-1]:.3e}  "
                              f"spread {hist.param_spread[-1]:.3e}")
                if ckpt_path and ckpt_every and (k + 1) % ckpt_every == 0:
                    flush()
                    writer.save(ckpt_path, state.params, step=k + 1, **ckpt_kw)
                    tel.counter("train.checkpoints")
        flush()
        if ckpt_path:
            writer.save(ckpt_path, state.params, step=steps, **ckpt_kw)
            tel.counter("train.checkpoints")
        if writer is not None:
            writer.close()        # surfaces background write errors
    except BaseException:
        # the loop is already failing: drain the writer but don't let a
        # secondary checkpoint-write error mask the real exception
        if writer is not None:
            try:
                writer.close()
            except Exception:
                pass
        raise
    return state, hist


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


# ---------------------------------------------------------------------------
# Event-driven simulated training (repro.sim)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """How a simulated fleet responds to step failures and rejoins.

    A failed step attempt (the ``fault_inject`` hook of
    :func:`run_simulated`) is retried after exponential backoff
    (``backoff_base * backoff_factor**attempt`` virtual seconds) up to
    ``max_retries`` times; once retries exhaust, the worker's parameter
    slice is restored — from the consensus average of the last checkpoint
    when ``ckpt_path`` is set and one has landed, else from the live
    fleet's current mean — and the step proceeds from the restored state.
    Rejoining workers (churn JOIN events) restore the same way. With
    ``ckpt_path`` set, the stacked state is checkpointed through the
    :class:`~repro.train.checkpoint.AsyncCheckpointWriter` every
    ``ckpt_every`` commits (sharded per worker when ``ckpt_sharded``).
    """

    max_retries: int = 3
    backoff_base: float = 0.5
    backoff_factor: float = 2.0
    ckpt_path: str | None = None
    ckpt_every: int = 10
    ckpt_sharded: bool = True

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if not self.backoff_base > 0:
            raise ValueError(f"backoff_base must be positive, got {self.backoff_base}")
        if not self.backoff_factor >= 1.0:
            raise ValueError(f"backoff_factor must be >= 1, got {self.backoff_factor}")
        if self.ckpt_every <= 0:
            raise ValueError(f"ckpt_every must be positive, got {self.ckpt_every}")


class _RecoveryManager:
    """Wires a :class:`RecoveryPolicy` into a sim protocol (its ``recovery``
    attribute): answers the per-attempt failure/backoff question, writes
    periodic consensus checkpoints, and restores failed/rejoining workers."""

    def __init__(self, policy: RecoveryPolicy, executor,
                 fault_inject: Callable[[int, int, int], bool] | None = None):
        self.policy = policy
        self.executor = executor
        self.fault_inject = fault_inject
        self.engine = None   # set by run_simulated once the Engine exists
        self.attempts: dict[tuple[int, int], int] = {}
        self.stats = {"step_failures": 0, "retries": 0, "restores": 0,
                      "rejoins": 0, "checkpoints": 0}
        self.writer = ckpt_lib.AsyncCheckpointWriter() \
            if policy.ckpt_path else None
        self._saved_any = False
        self._commits = 0

    # -- protocol hooks ---------------------------------------------------

    def step_failure_delay(self, j: int, k: int) -> float | None:
        """None → the attempt proceeds; a float → this attempt failed,
        retry after that many virtual seconds. Exhausted retries restore
        worker j and let the attempt proceed from the restored state."""
        if self.fault_inject is None:
            return None
        a = self.attempts.get((j, k), 0)
        if not self.fault_inject(j, k, a):
            self.attempts.pop((j, k), None)
            return None
        self.stats["step_failures"] += 1
        a += 1
        self.attempts[(j, k)] = a
        if a <= self.policy.max_retries:
            self.stats["retries"] += 1
            return self.policy.backoff_base * \
                self.policy.backoff_factor ** (a - 1)
        self.attempts.pop((j, k), None)
        self._restore(j)
        return None

    def after_commit(self, j: int, k: int) -> None:
        if self.writer is None:
            return
        self._commits += 1
        if self._commits % self.policy.ckpt_every == 0:
            self.writer.save(self.policy.ckpt_path, self.executor.W, step=k,
                             sharded=self.policy.ckpt_sharded)
            self._saved_any = True
            self.stats["checkpoints"] += 1

    def on_rejoin(self, j: int) -> None:
        self.stats["rejoins"] += 1
        self._restore(j)

    # -- restore ----------------------------------------------------------

    def _restore(self, j: int) -> None:
        """Overwrite worker j's slice with the latest consensus estimate:
        the worker-mean of the last sharded/monolithic checkpoint if one
        landed, else the live fleet's current mean (excluding j)."""
        self.stats["restores"] += 1
        ex = self.executor
        w = None
        if self.writer is not None and self._saved_any:
            self.writer.wait()   # the snapshot must be fully on disk
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), ex.W)
            stacked = ckpt_lib.restore(self.policy.ckpt_path, like=like)
            w = ckpt_lib.consensus_params(stacked)
        if w is None:
            mask = np.asarray(self.engine.alive).copy()
            mask[j] = False
            if not mask.any():
                mask[:] = True
            w = ex.mean_params(mask)
        ex.W = ex.set_slice(ex.W, j, w)

    def close(self) -> None:
        if self.writer is not None:
            self.writer.close()


@dataclasses.dataclass
class SimRun:
    """Result of a simulated run: final stacked state + the event trace."""

    params: PyTree           # (M, ...) stacked parameters at the end
    opt_state: PyTree
    trace: Any               # repro.sim.trace.Trace
    rounds: np.ndarray       # per-worker completed rounds
    virtual_time: float      # final virtual clock

    def loss_curve(self) -> tuple[np.ndarray, np.ndarray]:
        """(virtual times, per-round mean train-batch loss)."""
        return self.trace.round_loss_curve()

    def eval_curve(self) -> tuple[np.ndarray, np.ndarray]:
        """(virtual times, global loss of the worker-mean parameters)."""
        return self.trace.eval_curve()


def _meshless_payload_bytes(params_template: PyTree,
                            wire_dtype: str | None = None) -> int:
    """Per-message bytes of one whole-replica gossip payload: the bus
    layout-v2 plan's padded buffer for an unsharded (k = 1) replica
    (``wire_dtype`` prices the compressed DCI lane of the same plan)."""
    from repro.core.bus import plan_layout

    return plan_layout(params_template, lead_ndim=0).padded_bytes(wire_dtype)


def run_simulated(
    loss_fn: Callable[[PyTree, PyTree], jax.Array],
    params0: PyTree,
    optimizer: Optimizer,
    batches: Iterable[PyTree],
    *,
    gossip: GossipSpec,
    protocol: str = "sync",
    scenario=None,
    mesh=None,
    rounds: int = 100,
    eval_fn: Callable[[PyTree], float] | None = None,
    eval_every: int = 1,
    max_events: int | None = None,
    max_time: float | None = None,
    trace_path: str | None = None,
    barrier_timeout: float | None = None,
    degrade_mode: str = "reabsorb",
    commit: str = "slice",
    commit_batch: bool = True,
    snap_depth: int = 4,
    dci_dtype: str | None = None,
    recovery: RecoveryPolicy | None = None,
    fault_inject: Callable[[int, int, int], bool] | None = None,
    health: "bool | object" = False,
    run_dir: str | None = None,
) -> SimRun:
    """Train under virtual wall-clocks on the discrete-event simulator.

    Executes *real* train steps — the sync protocol runs the very
    ``make_train_step`` program ``train()`` jits, so with deterministic
    compute times its trajectory bit-matches the non-simulated loop — while
    the engine advances per-worker clocks through the scenario's straggler
    distribution, link delays, churn, and topology switches.

    Args:
      loss_fn / optimizer: as in :func:`train`.
      params0: stacked parameters with leading worker dim M
        (``replicate_for_workers``).
      batches: per-step batch iterable, leaves shaped (M, B, ...) — same
        contract as :func:`train`; replayed out-of-order via a cache for the
        asynchronous protocols.
      gossip: GossipSpec (topology + mixing backend; runs meshless).
      protocol: 'sync' | 'async' | 'stale' | 'hier'
        (see ``repro.sim.protocols``).
      scenario: ``repro.sim.Scenario`` (default: ideal unit-time world).
      mesh: makes the engine mesh-aware (two link classes): a
        ``sim.MeshSpec``, a ``launch.mesh.WorkerMesh`` (mirrored — worker
        groups from the pod axis, per-message payload bytes from the bus
        layout plan over ``params0``), or the string ``'topology'`` to adopt
        a hierarchical (kronecker) topology's own pod assignment. Required
        for scenarios with per-class ``link_classes`` costs.
      rounds: per-worker round budget (protocols stop scheduling past it).
      eval_fn: optional (mean-params pytree) -> float global loss; recorded
        per round (sync/hier: every `eval_every` rounds when the whole round
        completes; async/stale: every `eval_every` completed computations).
      trace_path: if set, write the JSON event trace there.
      barrier_timeout / degrade_mode: makes the barrier protocols
        (sync/hier) churn-capable — a worker whose barrier stalls for
        `barrier_timeout` virtual seconds commits over the snapshots that
        arrived, with the survivor-repaired weight column (`degrade_mode`
        'reabsorb' | 'renormalize'). Fault-free runs are unaffected.
      commit / commit_batch / snap_depth: barrier-protocol commit
        architecture. ``commit='slice'`` (default) runs the O(M) compiled
        per-slice step per completion, mixing over the round-tagged
        snapshot planes (``snap_depth`` deep); with ``commit_batch=True``
        same-instant completions additionally ride ONE vmapped per-slice
        step (disabled automatically when a recovery manager is attached).
        ``commit='full'`` opts back into the O(M²) full M-row reference
        program — bit-identical trajectories either way (asserted in CI;
        exception: ``adafactor_like``'s factored second moment is not
        worker-elementwise, use ``commit='full'`` for bit-exactness there —
        per-slice runs with such a coupled optimizer are rejected at
        construction).
      dci_dtype: 'bfloat16' | 'int8' | None — compress the cross-pod (DCI)
        stage of the ``hier`` protocol: outgoing cross-pod snapshots are
        quantized through the bus wire format with error feedback
        (``repro.sim.protocols.HierGossip``), and with a mesh attached the
        engine charges DCI messages the compressed wire bytes
        (``BusLayout.padded_bytes(dci_dtype)``) instead of the exact
        payload. Intra-pod traffic stays exact; ``None`` (default) is
        bit-identical to the uncompressed protocol.
      recovery / fault_inject: attach a :class:`RecoveryPolicy`.
        ``fault_inject(worker, round, attempt) -> bool`` marks a step
        attempt as failed (retried with backoff per the policy; restored
        from the last consensus checkpoint once retries exhaust). Passing
        either enables the recovery manager; its counters land in
        ``trace.meta['recovery']``.
      health: emit gossip-health gauges (spectral gap / effective number of
        neighbors of the ACTIVE — survivor-repaired, fault-blocked — mixing
        matrix) onto the trace timeline at t=0 and on every matrix-changing
        event. True for defaults, or a ``telemetry.HealthConfig``. Gauges
        are excluded from ``Trace.signature()``, so determinism tests and
        signature bit-match guarantees are unaffected.
      run_dir: if set, export the full telemetry bundle there —
        ``trace.json`` (provenance-stamped meta), ``perfetto.json``
        (Chrome-trace timeline, loadable at ui.perfetto.dev), and
        ``telemetry.json`` when a telemetry sink is active. Summarize with
        ``python -m repro.telemetry.report <run_dir>``. Implies saving the
        trace even without ``trace_path``.
    """
    from repro import sim

    proto_cls = sim.PROTOCOLS.get(protocol)
    if proto_cls is None:
        raise ValueError(f"unknown protocol {protocol!r}; "
                         f"choose from {sorted(sim.PROTOCOLS)}")
    proto_kw = {}
    if barrier_timeout is not None:
        if protocol not in ("sync", "hier"):
            raise ValueError(
                "barrier_timeout configures the barrier protocols "
                f"(sync/hier); protocol {protocol!r} has no barrier")
        proto_kw = dict(barrier_timeout=barrier_timeout,
                        degrade_mode=degrade_mode)
    if protocol in ("sync", "hier"):
        proto_kw.update(commit=commit, commit_batch=commit_batch,
                        snap_depth=snap_depth)
    elif commit != "slice":
        raise ValueError(
            "commit configures the barrier protocols (sync/hier); "
            f"protocol {protocol!r} has no commit mode")
    if dci_dtype is not None:
        if protocol != "hier":
            raise ValueError(
                "dci_dtype compresses the cross-pod (DCI) stage of the "
                f"hier protocol; protocol {protocol!r} has no DCI stage")
        proto_kw.update(dci_dtype=dci_dtype)
    if mesh is not None:
        from repro.launch.mesh import WorkerMesh

        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), params0)
        if mesh == "topology":
            mesh = sim.MeshSpec.from_topology(gossip.topology)
        elif isinstance(mesh, WorkerMesh):
            mesh = mesh.sim_spec(params_template=template,
                                 dci_dtype=dci_dtype)
        if isinstance(mesh, sim.MeshSpec) and not mesh.payload_bytes:
            # fill in the per-message wire bytes from the bus layout plan so
            # bandwidth terms and the per-class byte accounting are real
            mesh = dataclasses.replace(
                mesh, payload_bytes=_meshless_payload_bytes(template))
        if dci_dtype is not None and isinstance(mesh, sim.MeshSpec) and \
                not mesh.dci_payload_bytes:
            # cross-pod messages ship the quantized image: charge the
            # compressed wire bytes (same plan, wire pricing) on DCI links
            mesh = dataclasses.replace(
                mesh, dci_payload_bytes=_meshless_payload_bytes(
                    template, dci_dtype))
    executor = sim.TrainExecutor(loss_fn, optimizer, params0, batches,
                                 gossip, commit=commit)
    if executor.coupled and protocol == "hier":
        raise ValueError(
            "the hier protocol commits per worker slice in both commit "
            "modes (its commit='full' only changes the mix-source "
            "assembly), so optimizers with cross-worker-coupled state "
            "cannot run on it. Use protocol='sync' with commit='full', or "
            "a worker-elementwise optimizer.")
    proto = proto_cls(executor=executor, eval_fn=eval_fn,
                      eval_every=eval_every, **proto_kw)
    mgr = None
    if recovery is not None or fault_inject is not None:
        mgr = _RecoveryManager(recovery or RecoveryPolicy(), executor,
                               fault_inject)
        proto.recovery = mgr
    eng = sim.Engine(gossip.topology, scenario, mesh=mesh, health=health)
    if mgr is not None:
        mgr.engine = eng
    try:
        eng.run(proto, until_round=rounds, max_events=max_events,
                max_time=max_time)
    finally:
        if mgr is not None:
            mgr.close()
    if mgr is not None:
        eng.trace.meta["recovery"] = dict(mgr.stats)
        tel = telemetry.get()
        if tel.active:
            for k, v in mgr.stats.items():
                tel.counter(f"recovery.{k}", v)
    if trace_path:
        eng.trace.save(trace_path)
    if run_dir:
        from repro.telemetry.perfetto import save_perfetto

        eng.trace.meta["provenance"] = telemetry.provenance(
            config=dict(protocol=protocol, rounds=rounds,
                        topology=gossip.topology.name,
                        M=gossip.topology.M,
                        scenario=eng.scenario.describe()),
            writer="run_simulated")
        eng.trace.save(os.path.join(run_dir, "trace.json"))
        save_perfetto(eng.trace, os.path.join(run_dir, "perfetto.json"))
        tel = telemetry.get()
        if tel.active:
            tel.save(os.path.join(run_dir, "telemetry.json"))
    return SimRun(params=executor.W, opt_state=executor.opt, trace=eng.trace,
                  rounds=proto.rounds.copy(), virtual_time=eng.clock)
