"""Pytree checkpointing (numpy .npz — no external deps, restartable runs)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # numpy .npz cannot store ml_dtypes (bf16, fp8): widen to fp32;
            # restore() casts back to the target leaf dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree: PyTree, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    if step is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump({"step": int(step)}, f)


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of `like` (shapes/dtypes preserved)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_like = _flatten_with_paths(like)
    assert set(data.files) == set(flat_like), (
        sorted(set(data.files) ^ set(flat_like))[:5])
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(path: str) -> int | None:
    meta = path + ".meta.json"
    if os.path.exists(meta):
        with open(meta) as f:
            return json.load(f)["step"]
    return None
