"""Pytree checkpointing (numpy .npz — no external deps, restartable runs)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# Suffix marking a bf16 leaf stored as its raw 16-bit pattern. numpy .npz
# cannot store ml_dtypes, but a uint16 *view* keeps the exact bits at half
# the size of the old widen-to-fp32 fallback.
_BF16_TAG = "::bf16"


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if str(arr.dtype) == "bfloat16":
            key, arr = key + _BF16_TAG, np.ascontiguousarray(arr).view(np.uint16)
        elif arr.dtype.kind not in "fiub":
            # remaining ml_dtypes (fp8 etc.): widen to fp32 (lossless — fp8
            # values are exactly representable); restore() casts back.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree: PyTree, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    if step is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump({"step": int(step)}, f)


def _base_key(stored: str) -> str:
    return stored[:-len(_BF16_TAG)] if stored.endswith(_BF16_TAG) else stored


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of `like` (shapes/dtypes preserved).

    Storage-format agnostic: a leaf may be stored tagged (bf16 bit pattern)
    or plain (fp32-widened legacy checkpoints), independent of the dtype of
    `like` — only the *set of leaves* must match.
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    stored_by_key = {_base_key(f): f for f in data.files}
    like_keys = {_base_key(k) for k in _flatten_with_paths(like)}
    assert set(stored_by_key) == like_keys, (
        sorted(set(stored_by_key) ^ like_keys)[:5])
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        stored = stored_by_key[key]
        raw = data[stored]
        if stored.endswith(_BF16_TAG):
            raw = raw.view(jnp.bfloat16.dtype)
        arr = jnp.asarray(raw, dtype=leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(path: str) -> int | None:
    meta = path + ".meta.json"
    if os.path.exists(meta):
        with open(meta) as f:
            return json.load(f)["step"]
    return None
