"""Pytree checkpointing (numpy .npz — no external deps, restartable runs).

:class:`AsyncCheckpointWriter` moves the ``np.savez`` disk write off the
training-loop thread: ``save()`` snapshots the tree with a *device-side*
copy and returns immediately; a single background thread device-gets and
writes the snapshot while the loop keeps dispatching steps.
"""
from __future__ import annotations

import collections
import concurrent.futures
import json
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# Suffix marking a bf16 leaf stored as its raw 16-bit pattern. numpy .npz
# cannot store ml_dtypes, but a uint16 *view* keeps the exact bits at half
# the size of the old widen-to-fp32 fallback.
_BF16_TAG = "::bf16"


def _path_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _path_key(path)
        arr = np.asarray(leaf)
        if str(arr.dtype) == "bfloat16":
            key, arr = key + _BF16_TAG, np.ascontiguousarray(arr).view(np.uint16)
        elif arr.dtype.kind not in "fiub":
            # remaining ml_dtypes (fp8 etc.): widen to fp32 (lossless — fp8
            # values are exactly representable); restore() casts back.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _flatten_keys(tree: PyTree) -> set[str]:
    """Untagged leaf keys WITHOUT materializing leaves — works for abstract
    (ShapeDtypeStruct) templates as well as concrete arrays."""
    return {_path_key(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]}


def save(path: str, tree: PyTree, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    if step is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump({"step": int(step)}, f)


# ---------------------------------------------------------------------------
# Worker-sharded checkpoints (340B-scale: no full-tree funnel through host)
# ---------------------------------------------------------------------------


def _strip_npz(path: str) -> str:
    return path[:-len(".npz")] if path.endswith(".npz") else path


def worker_coords(wmesh, M: int) -> list[str]:
    """Shard keys in worker-index order: the WorkerMesh coordinates along
    the worker axes (row-major, e.g. ``'pod1-data3'`` on a pod×data mesh),
    or plain ``'w{j}'`` when no mesh is given (meshless stacked state)."""
    if wmesh is None:
        return [f"w{j}" for j in range(M)]
    axes = list(wmesh.worker_axes)
    sizes = [int(wmesh.mesh.shape[a]) for a in axes]
    if int(np.prod(sizes)) != M:
        raise ValueError(f"mesh hosts {int(np.prod(sizes))} workers, "
                         f"tree is stacked over {M}")
    out = []
    for j in range(M):
        rem, parts = j, []
        for a, s in zip(reversed(axes), reversed(sizes)):
            parts.append(f"{a}{rem % s}")
            rem //= s
        out.append("-".join(reversed(parts)))
    return out


def save_sharded(path: str, tree: PyTree, step: int | None = None, *,
                 wmesh=None) -> None:
    """Write one npz PER WORKER SHARD keyed by WorkerMesh coordinates.

    The plain :func:`save` path device-gets the full (M, …) stacked tree on
    one host before ``np.savez`` — at 340B scale that funnels M full
    replicas through host RAM. Here each worker's slice is pulled and
    written on its own (``{base}.shard-{coord}.npz``), so at most ONE
    replica is resident at a time; ``{base}.meta.json`` records the shard
    list for :func:`restore_sharded` to reassemble bit-exactly.
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        raise ValueError("cannot shard an empty tree")
    M = int(leaves[0].shape[0])
    if any(x.shape[:1] != (M,) for x in leaves):
        raise ValueError("sharded save needs a stacked tree (leading M dim)")
    base = _strip_npz(path)
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    coords = worker_coords(wmesh, M)
    for j, coord in enumerate(coords):
        # device-side slice, host transfer of ONE worker's replica at a time
        slice_j = jax.tree.map(lambda x: x[j], tree)
        np.savez(f"{base}.shard-{coord}.npz", **_flatten_with_paths(slice_j))
    meta: dict[str, Any] = {"sharded": {"shards": coords}}
    if step is not None:
        meta["step"] = int(step)
    with open(base + ".meta.json", "w") as f:
        json.dump(meta, f)
    # a monolithic checkpoint left at the same base is now stale — remove it
    # so restore() can never silently prefer the older full-tree file
    for stale in (base + ".npz", base + ".npz.meta.json"):
        if os.path.exists(stale):
            os.remove(stale)


def _sharded_meta(path: str) -> dict | None:
    meta = _strip_npz(path) + ".meta.json"
    if os.path.exists(meta):
        with open(meta) as f:
            d = json.load(f)
        if "sharded" in d:
            return d
    return None


def restore_sharded(path: str, like: PyTree) -> PyTree:
    """Reassemble a :func:`save_sharded` checkpoint into `like`'s structure
    (a stacked tree with leading M dim; abstract templates work). Stacking
    the per-worker bit patterns in shard order is the exact inverse of the
    per-slice save — round-trips are bit-exact, bf16 tags included."""
    base = _strip_npz(path)
    meta = _sharded_meta(path)
    if meta is None:
        raise FileNotFoundError(f"{base}.meta.json has no shard list")
    shards = [np.load(f"{base}.shard-{c}.npz")
              for c in meta["sharded"]["shards"]]
    stored_by_key = {_base_key(f): f for f in shards[0].files}
    like_keys = _flatten_keys(like)
    assert set(stored_by_key) == like_keys, (
        sorted(set(stored_by_key) ^ like_keys)[:5])
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_paths:
        stored = stored_by_key[_path_key(path_k)]
        raw = np.stack([s[stored] for s in shards])
        if stored.endswith(_BF16_TAG):
            raw = raw.view(jnp.bfloat16.dtype)
        arr = jnp.asarray(raw, dtype=leaf.dtype)
        assert arr.shape == leaf.shape, (stored, arr.shape, leaf.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def consensus_from_sharded(path: str, like: PyTree, *,
                           shardings: PyTree | None = None) -> PyTree:
    """Consensus average w̄ = (1/M)Σ w_j straight from a worker-sharded
    checkpoint, with at most ONE worker replica on host at a time.

    :func:`restore_sharded` / :func:`export_consensus` stack all M shards on
    host before averaging — M full replicas of host RAM, a non-starter at
    340B. Here each shard is opened in turn, its leaves placed on device
    (against per-leaf ``shardings`` when given, so the result lands directly
    in the serving layout), cast to fp32 and added into a running sum; the
    divide by M happens once at the end, then casts back to `like`'s dtypes.
    Shards accumulate in meta order, so the result is deterministic and
    matches the full-restore ``consensus_params`` reduction order.
    """
    base = _strip_npz(path)
    meta = _sharded_meta(path)
    if meta is None:
        raise FileNotFoundError(f"{base}.meta.json has no shard list")
    coords = meta["sharded"]["shards"]
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = [_path_key(pk) for pk, _ in leaves_paths]
    if shardings is not None:
        shard_leaves, sh_def = jax.tree_util.tree_flatten(shardings)
        assert sh_def == treedef, "shardings must mirror `like`"
    else:
        shard_leaves = [None] * len(keys)
    acc: list | None = None
    stored_by_key: dict[str, str] | None = None
    for c in coords:
        with np.load(f"{base}.shard-{c}.npz") as z:
            if stored_by_key is None:
                stored_by_key = {_base_key(f): f for f in z.files}
                assert set(stored_by_key) == set(keys), (
                    sorted(set(stored_by_key) ^ set(keys))[:5])
            cur = []
            for key, (_, leaf), sh in zip(keys, leaves_paths, shard_leaves):
                stored = stored_by_key[key]
                raw = z[stored]
                if stored.endswith(_BF16_TAG):
                    raw = raw.view(jnp.bfloat16.dtype)
                assert raw.shape == leaf.shape, (key, raw.shape, leaf.shape)
                x = jax.device_put(raw, sh) if sh is not None \
                    else jnp.asarray(raw)
                cur.append(x.astype(jnp.float32))
        acc = cur if acc is None else [a + b for a, b in zip(acc, cur)]
    Mw = jnp.float32(len(coords))
    out = [(a / Mw).astype(leaf.dtype)
           for a, (_, leaf) in zip(acc, leaves_paths)]
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointWriter:
    """Background checkpoint writer: snapshot on call, ``np.savez`` off-thread.

    The train loop donates its state, so the step-k params buffers are
    overwritten in place by step k+1 — handing the *live* arrays to a writer
    thread would race the donation (torn read, or a deleted-buffer error).
    ``save()`` therefore dispatches a device-side ``x.copy()`` of every leaf
    first: the copy is enqueued on the device stream *before* the next step
    can reuse the buffer, so it is dataflow-ordered against donation and
    never blocks the host on the device. The snapshot then goes to a single
    background thread that performs the (blocking) device→host transfer and
    the ``np.savez`` disk write.

    At most ``max_pending`` snapshots are in flight; a further ``save()``
    first waits on the oldest (bounded snapshot memory). ``wait()`` drains
    the queue and re-raises any writer-thread exception.

    Transient IO errors (``OSError`` from the filesystem — full disk that
    drains, flaky network mount) are retried up to ``io_retries`` times with
    exponential backoff starting at ``io_backoff`` seconds. A write that
    exhausts its retries puts the writer in *terminal failure*: the error
    surfaces on the next ``save()`` (as well as on ``wait()``/``close()``),
    so a training loop cannot silently keep running while every checkpoint
    is lost.

    ``save(..., wmesh=…)`` (or any non-None ``wmesh``-like sentinel) routes
    the write through :func:`save_sharded`: the background thread pulls ONE
    worker slice of the device-side snapshot at a time and writes per-shard
    npz files keyed by the WorkerMesh coordinates — 340B-scale stacked state
    never funnels through host RAM in full.
    """

    def __init__(self, max_pending: int = 2, *, io_retries: int = 3,
                 io_backoff: float = 0.05):
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="ckpt-writer")
        self._pending: collections.deque = collections.deque()
        self._max_pending = max(1, max_pending)
        self._io_retries = max(1, int(io_retries))
        self._io_backoff = io_backoff
        self._terminal: BaseException | None = None

    def _write(self, fn, *args, **kw):
        delay = self._io_backoff
        for attempt in range(self._io_retries):
            try:
                return fn(*args, **kw)
            except OSError as e:
                if attempt == self._io_retries - 1:
                    self._terminal = e
                    raise
                time.sleep(delay)
                delay *= 2

    def save(self, path: str, tree: PyTree, step: int | None = None, *,
             wmesh=None, sharded: bool = False) -> None:
        if self._terminal is not None:
            raise RuntimeError(
                f"checkpoint writer failed terminally after "
                f"{self._io_retries} attempts: {self._terminal}"
            ) from self._terminal
        snap = jax.tree.map(
            lambda x: x.copy() if hasattr(x, "copy") else x, tree)
        while len(self._pending) >= self._max_pending:
            self._pending.popleft().result()
        if sharded or wmesh is not None:
            fut = self._pool.submit(self._write, save_sharded, path, snap,
                                    step, wmesh=wmesh)
        else:
            fut = self._pool.submit(self._write, save, path, snap, step)
        self._pending.append(fut)

    def wait(self) -> None:
        while self._pending:
            self._pending.popleft().result()

    def close(self) -> None:
        try:
            self.wait()
        finally:
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "AsyncCheckpointWriter":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def _base_key(stored: str) -> str:
    return stored[:-len(_BF16_TAG)] if stored.endswith(_BF16_TAG) else stored


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of `like` (shapes/dtypes preserved).

    Storage-format agnostic: a leaf may be stored tagged (bf16 bit pattern)
    or plain (fp32-widened legacy checkpoints), independent of the dtype of
    `like` — only the *set of leaves* must match. `like` leaves only need
    ``.shape``/``.dtype``, so abstract ``ShapeDtypeStruct`` templates work —
    no zero-tree allocation for large restores. Worker-sharded checkpoints
    (:func:`save_sharded`) are detected via their meta and reassembled.
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    if not os.path.exists(path) and _sharded_meta(path) is not None:
        return restore_sharded(path, like)
    data = np.load(path)
    stored_by_key = {_base_key(f): f for f in data.files}
    like_keys = _flatten_keys(like)
    assert set(stored_by_key) == like_keys, (
        sorted(set(stored_by_key) ^ like_keys)[:5])
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_paths:
        key = _path_key(path_k)
        stored = stored_by_key[key]
        raw = data[stored]
        if stored.endswith(_BF16_TAG):
            raw = raw.view(jnp.bfloat16.dtype)
        arr = jnp.asarray(raw, dtype=leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def consensus_params(params_M: PyTree) -> PyTree:
    """Average the leading worker dim away: one serving replica.

    A gossip-trained checkpoint stores every worker's estimate w_j stacked on
    a leading M dim; the paper's output model is the consensus average
    w̄ = (1/M) Σ_j w_j. Averaging happens in fp32 and casts back, so bf16
    checkpoints don't lose a bit more than the final cast."""
    return jax.tree.map(
        lambda x: jnp.mean(jnp.asarray(x, jnp.float32), axis=0).astype(x.dtype),
        params_M)


def export_consensus(src: str | PyTree, dst: str | None = None,
                     step: int | None = None) -> PyTree:
    """Collapse a gossip checkpoint (leading worker dim) to a serving one.

    ``src`` is a checkpoint path (leaves loaded as stored — monolithic or
    worker-sharded ``save_sharded`` layouts both work) or an in-memory
    worker-stacked pytree. The averaged single-replica tree is returned and,
    when ``dst`` is given, saved as a normal checkpoint that
    ``serving.engine.load_consensus_params`` (or plain :func:`restore`)
    can feed straight into prefill/decode."""
    if isinstance(src, str):
        path = src if src.endswith(".npz") else src + ".npz"
        meta = None if os.path.exists(path) else _sharded_meta(path)
        if meta is not None:
            # worker-sharded checkpoint: stack the per-shard bit patterns in
            # shard order (the restore_sharded inverse), tags preserved so
            # bf16 leaves view back losslessly before the fp32 averaging
            base = _strip_npz(path)
            shards = [np.load(f"{base}.shard-{c}.npz")
                      for c in meta["sharded"]["shards"]]
            leaves = {}
            for stored in shards[0].files:
                raw = np.stack([s[stored] for s in shards])
                if stored.endswith(_BF16_TAG):
                    raw = raw.view(jnp.bfloat16.dtype)
                leaves[_base_key(stored)] = raw
            tree = _unflatten_keys(leaves)
            if step is None:
                step = meta.get("step")
        else:
            data = np.load(path)
            leaves = {}
            for stored in data.files:
                raw = data[stored]
                if stored.endswith(_BF16_TAG):
                    raw = raw.view(jnp.bfloat16.dtype)
                leaves[_base_key(stored)] = raw
            tree = _unflatten_keys(leaves)
            if step is None:
                # save() keys the .meta.json on the caller's spelling, which
                # may or may not include the .npz suffix — probe both.
                step = latest_step(path)
                if step is None and path != src:
                    step = latest_step(src)
    else:
        tree = src
    mean = consensus_params(tree)
    if dst is not None:
        save(dst, mean, step=step)
    return mean


def _unflatten_keys(flat: dict[str, Any]) -> PyTree:
    """'a/b/0' keyed dict → nested dict tree (lists stay int-keyed dicts —
    consensus averaging and re-saving only need the leaves + stable keys)."""
    out: dict[str, Any] = {}
    for key, leaf in flat.items():
        node = out
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return out


def latest_step(path: str) -> int | None:
    meta = path + ".meta.json"
    if os.path.exists(meta):
        with open(meta) as f:
            # sharded metas always exist but carry 'step' only when given
            return json.load(f).get("step")
    return None
