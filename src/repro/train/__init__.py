from repro.train import checkpoint, loop
from repro.train.loop import History, train

__all__ = ["checkpoint", "loop", "History", "train"]
