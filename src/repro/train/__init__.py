from repro.train import checkpoint, loop
from repro.train.loop import (History, RecoveryPolicy, SimRun,
                             run_simulated, train)

__all__ = ["checkpoint", "loop", "History", "train", "SimRun",
           "run_simulated", "RecoveryPolicy"]
